"""Attention for all assigned families.

TPU adaptation highlights (see DESIGN.md):

* **Head padding for 16-way TP.** q heads are padded to `Hp`, the smallest
  multiple of lcm(tp, n_kv) >= n_heads (GQA) or of tp (MHA, kv padded too).
  Padded q heads read kv head 0 and their output-projection rows are zero, so
  the function computed is exactly the unpadded model. The layout is
  *pre-grouped*: q head p belongs to kv group p // (Hp // KVp), with real
  heads occupying the leading slots of each group — this keeps plain
  `jnp.repeat` GQA expansion and grouped decode einsums correct even when
  padded.

* **Blockwise (flash-structured) prefill/train attention.** q is processed in
  static blocks unrolled at trace time; each block attends to a *statically
  sliced* k range (causal upper bound, sliding-window lower bound), so HLO
  FLOPs equal true causal/windowed FLOPs — no wasted upper-triangle compute,
  and the (block_q, k_len) score tile bounds live memory. This mirrors the
  Pallas flash kernel's tiling (kernels/flash_attention.py is the TPU target;
  this is the XLA path used for dry-run compilation).

* **Decode = sequence-sharded flash-decoding.** The KV cache shards its seq
  dim over the `model` mesh axis ("kv_seq"); q and the output are replicated
  within a model row and XLA inserts the tiny softmax all-reduces. This works
  for every kv-head count (1, 2, 8, 12, ...) where head-sharding cannot.

* **MLA (DeepSeek-V2)** implements both the decompressed prefill form and the
  *absorbed* decode form against the compressed (kv_lora + rope) cache.
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models import modules as nn
from repro.sharding import lshard


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


@dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv: int
    head_dim: int
    heads_padded: int       # Hp
    kv_padded: int          # KVp
    tp: int

    @property
    def group(self) -> int:
        return self.heads_padded // self.kv_padded

    @property
    def real_group(self) -> int:
        return self.n_heads // self.n_kv

    def real_head_mask(self) -> jnp.ndarray:
        """(Hp,) 1.0 for real q-head slots in the pre-grouped layout."""
        g, rg = self.group, self.real_group
        slot = jnp.arange(self.heads_padded)
        kv_real = (slot // g) < self.n_kv
        return ((slot % g < rg) & kv_real).astype(jnp.float32)


def attn_dims(cfg: ModelConfig, tp: int) -> AttnDims:
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    if H % tp == 0:
        Hp, KVp = H, KV
    elif H == KV:  # MHA: pad both
        Hp = KVp = ((H + tp - 1) // tp) * tp
    else:          # GQA: pad q heads only, keep kv-groupable
        base = _lcm(tp, KV)
        Hp = ((H + base - 1) // base) * base
        KVp = KV
    return AttnDims(H, KV, hd, Hp, KVp, tp)


# ----------------------------------------------------------------------------
# Parameters
# ----------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, tp: int, dtype):
    d = cfg.d_model
    dims = attn_dims(cfg, tp)
    hd = dims.head_dim
    ks = jax.random.split(key, 4)
    bias = cfg.qkv_bias
    q8 = cfg.quant_int8
    p = {
        "wq": nn.init_linear(ks[0], d, (dims.heads_padded, hd), bias=bias,
                             dtype=dtype, quant=q8),
        "wk": nn.init_linear(ks[1], d, (dims.kv_padded, hd), bias=bias,
                             dtype=dtype, quant=q8),
        "wv": nn.init_linear(ks[2], d, (dims.kv_padded, hd), bias=bias,
                             dtype=dtype, quant=q8),
        "wo": nn.init_linear(ks[3], (dims.heads_padded * hd), d,
                             bias=cfg.mlp_bias, dtype=dtype, quant=q8),
    }

    def _mask_out(pp, out_mask=None, in_mask=None):
        """Zero padded slots exactly. out_mask broadcasts over output
        channels (scale-zero for quantized); in_mask over input rows
        (applied to the stored weight)."""
        if "w_scale" in pp:
            if out_mask is not None:
                pp["w_scale"] = pp["w_scale"] * out_mask.astype(
                    pp["w_scale"].dtype)
            if in_mask is not None:
                pp["w_q8"] = pp["w_q8"] * in_mask.astype(jnp.int8)
        else:
            w = pp["w"]
            if out_mask is not None:
                w = w * out_mask.astype(w.dtype)[None]
            if in_mask is not None:
                w = w * in_mask.astype(w.dtype)
            pp["w"] = w

    mask_q = dims.real_head_mask().astype(dtype)
    _mask_out(p["wq"], out_mask=mask_q[:, None])
    if bias:
        p["wq"]["b"] = p["wq"]["b"] * mask_q[:, None]
    if dims.kv_padded != dims.n_kv:
        mk = (jnp.arange(dims.kv_padded) < dims.n_kv).astype(dtype)
        for nm in ("wk", "wv"):
            _mask_out(p[nm], out_mask=mk[:, None])
            if bias:
                p[nm]["b"] = p[nm]["b"] * mk[:, None]
    wo_mask = jnp.repeat(mask_q, hd).astype(dtype)
    _mask_out(p["wo"], in_mask=wo_mask[:, None])
    return p


def attention_specs(cfg: ModelConfig):
    bias = cfg.qkv_bias

    def lin(in_names, out_names, b):
        s = nn.linear_specs(in_names, out_names, quant=cfg.quant_int8)
        if b:
            s["b"] = tuple(out_names)
        return s

    return {
        "wq": lin(("embed",), ("heads", None), bias),
        "wk": lin(("embed",), ("kv_heads", None), bias),
        "wv": lin(("embed",), ("kv_heads", None), bias),
        "wo": lin(("heads",), ("embed",), cfg.mlp_bias),
    }


# ----------------------------------------------------------------------------
# Blockwise masked attention (train / prefill)
# ----------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, *, causal: bool, window: int, prefix_len) -> jnp.ndarray:
    """Additive bias (q, k) in fp32; -inf where disallowed."""
    allowed = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok = q_pos[:, None] >= k_pos[None, :]
        if prefix_len is not None:
            ok = ok | ((q_pos[:, None] < prefix_len) & (k_pos[None, :] < prefix_len))
        allowed &= ok
    if window > 0:
        allowed &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(allowed, 0.0, -1e30).astype(jnp.float32)


def blockwise_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        prefix_len: Optional[int] = None, block_q: int = 512,
                        softmax_scale: Optional[float] = None) -> jnp.ndarray:
    """q (b,sq,H,hd); k,v (b,sk,H,hd) — already GQA-expanded.

    Unrolls q into static blocks; each block's k range is statically sliced
    to [lo, hi) where hi enforces causality and lo the sliding window, so the
    compiled FLOPs match the true masked FLOPs.
    """
    b, sq, H, hd = q.shape
    sk = k.shape[1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    aligned = causal and (sq == sk) and prefix_len is None
    out_blocks = []
    n_blocks = (sq + block_q - 1) // block_q
    for i in range(n_blocks):
        qs, qe = i * block_q, min(sq, (i + 1) * block_q)
        if aligned:
            hi = qe
            lo = max(0, qs - window + 1) if window > 0 else 0
        else:
            hi, lo = sk, 0
        qb = q[:, qs:qe]
        kb, vb = k[:, lo:hi], v[:, lo:hi]
        scores = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                            preferred_element_type=jnp.float32) * scale
        q_pos = jnp.arange(qs, qe)
        k_pos = jnp.arange(lo, hi)
        scores = scores + _mask_bias(q_pos, k_pos, causal=causal,
                                     window=window, prefix_len=prefix_len)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out_blocks.append(jnp.einsum("bhqk,bkhd->bqhd", w, vb))
    return jnp.concatenate(out_blocks, axis=1) if len(out_blocks) > 1 else out_blocks[0]


def gqa_expand(kv: jnp.ndarray, dims: AttnDims) -> jnp.ndarray:
    """(b,s,KVp,hd) -> (b,s,Hp,hd) via the pre-grouped repeat."""
    if dims.kv_padded == dims.heads_padded:
        return kv
    return jnp.repeat(kv, dims.group, axis=2)


def attention_forward(p, x: jnp.ndarray, dims: AttnDims, *,
                      cos, sin, causal: bool = True, window: int = 0,
                      prefix_len: Optional[int] = None,
                      kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                      block_q: int = 512) -> jnp.ndarray:
    """Full-sequence attention (train / prefill). x (b,s,d)."""
    q = nn.linear(p["wq"], x)                               # (b,s,Hp,hd)
    q = lshard(q, "batch", None, "heads", None)
    if kv_override is None:
        k = nn.linear(p["wk"], x)
        v = nn.linear(p["wv"], x)
    else:
        k, v = kv_override
    if cos is not None:
        q = nn.apply_rope(q, cos, sin)
        if kv_override is None:
            k = nn.apply_rope(k, cos, sin)
    k = gqa_expand(k, dims)
    v = gqa_expand(v, dims)
    o = blockwise_attention(q, k, v, causal=causal, window=window,
                            prefix_len=prefix_len, block_q=block_q)
    o = o.reshape(*x.shape[:-1], dims.heads_padded * dims.head_dim)
    o = lshard(o, "batch", None, "heads")
    return nn.linear(p["wo"], o)


# ----------------------------------------------------------------------------
# Decode (single new token, seq-sharded KV cache)
# ----------------------------------------------------------------------------

def init_kv_cache(batch: int, cache_len: int, dims: AttnDims, dtype,
                  kv_quant: Optional[str] = None) -> dict:
    if kv_quant == "int8":
        # int8 block stores + per-(block, kv-head) fp32 scales. The scale
        # leaf's middle axis is a singleton stand-in for the seq axis: its
        # spec carries "kv_seq" so the paged pool flags it as paged and the
        # pool's block-granular COW copy moves a block's scale together
        # with its block id (see serving/paged_pool.py).
        shape = (batch, cache_len, dims.kv_padded, dims.head_dim)
        return {
            "k_q8": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros((batch, 1, dims.kv_padded), jnp.float32),
            "v_q8": jnp.zeros(shape, jnp.int8),
            "v_scale": jnp.zeros((batch, 1, dims.kv_padded), jnp.float32),
        }
    assert kv_quant is None, f"unknown kv_quant mode: {kv_quant!r}"
    return {
        "k": jnp.zeros((batch, cache_len, dims.kv_padded, dims.head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, dims.kv_padded, dims.head_dim), dtype),
    }


# ----------------------------------------------------------------------------
# Paged KV primitives (block-granular cache; see serving/paged_pool.py)
#
# A paged cache leaf is (n_blocks, B, ...): physical blocks of B positions
# shared by all sequences. Each sequence owns a block table (b, T) mapping
# logical block t (positions t*B .. t*B+B-1) to a physical block id, so
# logical position p lives at (table[p // B], p % B).
# ----------------------------------------------------------------------------

def paged_write(blocks: jnp.ndarray, new: jnp.ndarray, tables: jnp.ndarray,
                pos: jnp.ndarray) -> jnp.ndarray:
    """Write one new row per sequence into its current block.

    blocks (nb, B, ...); new (b, ...); tables (b, T); pos (b,). The block
    being written must be exclusively owned by its sequence (COW gives
    every live sequence a private boundary block), so scatter indices are
    unique across live rows; retired rows all alias the reserved null
    block, whose contents are never read.
    """
    nb, B = blocks.shape[0], blocks.shape[1]
    flat = blocks.reshape((nb * B,) + blocks.shape[2:])
    bidx = jnp.take_along_axis(tables, (pos // B)[:, None], axis=1)[:, 0]
    flat = flat.at[bidx * B + pos % B].set(new.astype(blocks.dtype))
    return flat.reshape(blocks.shape)


def paged_write_chunk(blocks: jnp.ndarray, new: jnp.ndarray,
                      tables: jnp.ndarray, pos: jnp.ndarray,
                      valid: jnp.ndarray) -> jnp.ndarray:
    """Write up to C new rows per sequence (varlen chunked prefill).

    blocks (nb, B, ...); new (b, C, ...); tables (b, T); pos (b,) start
    position of each sequence's chunk; valid (b,) how many of its C rows
    are real. Row j of sequence i lands at logical position pos[i] + j;
    rows past valid[i] are redirected into the reserved null block (their
    contents are never read, and colliding null-row scatters are harmless
    for the same reason). Valid rows write only into blocks the sequence
    exclusively owns — chunked prefill allocates fresh blocks ahead of the
    write and shared (radix/COW) blocks are never below the write range —
    so real scatter indices stay unique across sequences.
    """
    nb, B = blocks.shape[0], blocks.shape[1]
    b, C = new.shape[0], new.shape[1]
    T = tables.shape[1]
    flat = blocks.reshape((nb * B,) + blocks.shape[2:])
    p = pos[:, None] + jnp.arange(C)[None, :]                   # (b, C)
    lb = jnp.clip(p // B, 0, T - 1)
    bidx = jnp.take_along_axis(tables, lb, axis=1)              # (b, C)
    ok = jnp.arange(C)[None, :] < valid[:, None]
    idx = jnp.where(ok, bidx * B + p % B, p % B)                # null blk
    flat = flat.at[idx.reshape(-1)].set(
        new.reshape((b * C,) + new.shape[2:]).astype(blocks.dtype))
    return flat.reshape(blocks.shape)


def paged_gather(blocks: jnp.ndarray, tables: jnp.ndarray) -> jnp.ndarray:
    """Gather each sequence's blocks into a dense (b, T*B, ...) view.

    Rows past a sequence's live length read whatever the padding table
    entries point at — always finite values (block stores are zero-init
    and only ever overwritten by real K/V) — and are masked by the
    `<= pos` validity rule downstream, contributing exact zeros to the
    softmax. This is the XLA path; REPRO_DECODE_KERNEL=pallas streams the
    blocks through `kernels.paged_decode_attention` without densifying.
    """
    nb, B = blocks.shape[0], blocks.shape[1]
    flat = blocks.reshape((nb * B,) + blocks.shape[2:])
    idx = (tables[:, :, None] * B
           + jnp.arange(B)[None, None, :]).reshape(tables.shape[0], -1)
    return flat[idx]


def kv_cache_specs(kv_quant: Optional[str] = None) -> dict:
    if kv_quant == "int8":
        # "kv_seq" on the scale leaves' singleton axis makes the paged pool
        # flag them paged, so block-granular COW/radix machinery carries a
        # block's scale with its block id untouched.
        return {"k_q8": ("batch", "kv_seq", None, None),
                "k_scale": ("batch", "kv_seq", None),
                "v_q8": ("batch", "kv_seq", None, None),
                "v_scale": ("batch", "kv_seq", None)}
    assert kv_quant is None, f"unknown kv_quant mode: {kv_quant!r}"
    return {"k": ("batch", "kv_seq", None, None),
            "v": ("batch", "kv_seq", None, None)}


# ----------------------------------------------------------------------------
# Quantized paged KV: int8 block stores, per-(block, kv-head) fp32 scales.
#
# Writes requantize the whole target block around the inserted rows: gather
# the block(s), dequantize with the current scale, insert, recompute a fresh
# symmetric amax/127 scale per (block, kv-head), requantize, scatter blocks
# and scales back together. Existing rows requantize exactly under an
# unchanged scale (round(q * s / s) == q, and the amax row dequantizes to
# 127*s exactly, so the recomputed scale is bit-stable); only a write that
# RAISES the block amax re-rounds older rows under the new scale, so error
# is bounded by one half-step per amax growth — at most B half-steps per
# block, not one per rewrite. Zero blocks keep scale 0, so dequantization
# of never-written (null / padding) blocks is exactly zero.
# ----------------------------------------------------------------------------

def _quantize_block(deq: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """deq (..., B, KVp, hd) fp32 -> (int8 of same shape, scales (..., KVp))
    under per-(..., kv-head) symmetric amax/127 scales."""
    amax = jnp.max(jnp.abs(deq), axis=(-3, -1))                 # (..., KVp)
    sc = amax / 127.0
    denom = jnp.where(sc > 0, sc, 1.0)
    q8 = jnp.clip(jnp.round(deq / denom[..., None, :, None]), -127, 127)
    return q8.astype(jnp.int8), sc


def paged_write_quant(blocks: jnp.ndarray, scales: jnp.ndarray,
                      new: jnp.ndarray, tables: jnp.ndarray,
                      pos: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantized `paged_write`: one new row per sequence, whole-block requant.

    blocks (nb, B, KVp, hd) int8; scales (nb, 1, KVp) fp32; new (b, KVp, hd);
    tables (b, T); pos (b,). Ownership rules are identical to `paged_write`:
    the target block is exclusively owned (COW boundary block), retired rows
    alias the reserved null block whose contents are never read.
    """
    B = blocks.shape[1]
    T = tables.shape[1]
    lb = jnp.clip(pos // B, 0, T - 1)
    bidx = jnp.take_along_axis(tables, lb[:, None], axis=1)[:, 0]   # (b,)
    deq = blocks[bidx].astype(jnp.float32) * scales[bidx][..., None]
    sel = jnp.arange(B)[None, :] == (pos % B)[:, None]              # (b, B)
    deq = jnp.where(sel[:, :, None, None],
                    new.astype(jnp.float32)[:, None], deq)
    q8, sc = _quantize_block(deq)
    return blocks.at[bidx].set(q8), scales.at[bidx].set(sc[:, None, :])


def paged_write_chunk_quant(blocks: jnp.ndarray, scales: jnp.ndarray,
                            new: jnp.ndarray, tables: jnp.ndarray,
                            pos: jnp.ndarray, valid: jnp.ndarray
                            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantized `paged_write_chunk`: whole-window requant.

    new (b, C, KVp, hd); the window is the NT = ceil(C/B) + 1 logical blocks
    from pos // B, enough to hold any alignment of C rows. Window entries
    past the table (or unallocated, i.e. table value 0) resolve to the
    reserved null block: no valid row ever lands there (prefill allocates
    ahead of the write), its scale stays 0 and its contents are never read,
    so colliding null scatters are harmless exactly as in
    `paged_write_chunk`. Real gathered blocks at or above pos // B are
    exclusively owned (radix-published prefixes end on block boundaries
    below the write range), so real scatter indices stay unique across
    sequences, and gathered-but-untouched blocks requantize to themselves.
    """
    B = blocks.shape[1]
    b, C = new.shape[0], new.shape[1]
    T = tables.shape[1]
    NT = -(-C // B) + 1
    lb0 = pos // B
    oj = lb0[:, None] + jnp.arange(NT)[None, :]                     # (b, NT)
    bidx = jnp.take_along_axis(tables, jnp.clip(oj, 0, T - 1), axis=1)
    bidx = jnp.where(oj < T, bidx, 0)                               # null blk
    deq = blocks[bidx].astype(jnp.float32) * scales[bidx][..., None]
    c = lb0[:, None] * B + jnp.arange(NT * B)[None, :] - pos[:, None]
    ok = (c >= 0) & (c < valid[:, None])                            # (b, NT*B)
    rows = jnp.take_along_axis(new.astype(jnp.float32),
                               jnp.clip(c, 0, C - 1)[:, :, None, None],
                               axis=1)                              # (b,NT*B,KVp,hd)
    deq = deq.reshape((b, NT * B) + deq.shape[3:])
    deq = jnp.where(ok[:, :, None, None], rows, deq)
    deq = deq.reshape((b, NT, B) + deq.shape[2:])
    q8, sc = _quantize_block(deq)
    blocks = blocks.at[bidx.reshape(-1)].set(
        q8.reshape((b * NT,) + q8.shape[2:]))
    scales = scales.at[bidx.reshape(-1)].set(
        sc.reshape(b * NT, 1, sc.shape[-1]))
    return blocks, scales


def paged_gather_dequant(blocks: jnp.ndarray, scales: jnp.ndarray,
                         tables: jnp.ndarray, dtype) -> jnp.ndarray:
    """Explicit-dequant XLA fallback view: gather int8 blocks and their
    scales into a dense (b, T*B, KVp, hd) cache in `dtype`. Padding table
    entries alias the null block (scale 0 -> exact zeros), masked by the
    `<= pos` validity rule downstream like the fp gather path."""
    B = blocks.shape[1]
    q = paged_gather(blocks, tables)                    # (b, T*B, KVp, hd)
    s = paged_gather(scales, tables)                    # (b, T,   KVp)
    s = jnp.repeat(s, B, axis=1)                        # (b, T*B, KVp)
    return (q.astype(jnp.float32) * s[..., None]).astype(dtype)


def _write_slot(buf: jnp.ndarray, new: jnp.ndarray, slot: jnp.ndarray) -> jnp.ndarray:
    """Write new (b,1,...) at per-batch slot (b,) into buf (b,S,...).

    Masked-select write: uniformly shardable on the seq axis (a vmap'd
    dynamic_update_slice forces GSPMD to reshard); costs one extra cache
    read/write which we account for in the roofline notes.
    """
    S = buf.shape[1]
    sel = jnp.arange(S)[None, :] == slot[:, None]           # (b,S)
    sel = sel.reshape(sel.shape + (1,) * (buf.ndim - 2))
    return jnp.where(sel, new.astype(buf.dtype), buf)


def _grouped_decode_scores(q, ck, cv, positions, dims: AttnDims, dtype):
    """Grouped-einsum attention of Q query tokens against a dense per-row
    cache view ck/cv (b, S, KVp, hd) with per-query `idx <= positions`
    validity. q (b, Q, Hp, hd); positions (b, Q). Shared by the slot path,
    the paged gather path (Q = 1) and varlen chunked prefill (Q = chunk):
    extra masked rows contribute exact zeros, so the result is invariant
    to S padding, and each query row's math is independent of its
    batch-mates, so chunk placement does not perturb values."""
    b, Q = q.shape[0], q.shape[1]
    S = ck.shape[1]
    g = dims.group
    qg = q.reshape(b, Q, dims.kv_padded, g, dims.head_dim)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, ck,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(dims.head_dim)
    valid = jnp.arange(S)[None, None, :] <= positions[:, :, None]  # (b,Q,S)
    bias = jnp.where(valid, 0.0, -1e30)[:, None, None, :, :]
    w = jax.nn.softmax(scores + bias, axis=-1).astype(dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, cv)
    return o.reshape(b, Q, dims.heads_padded * dims.head_dim)


def attention_decode(p, x: jnp.ndarray, cache: dict, pos: jnp.ndarray,
                     dims: AttnDims, *, rope_theta: float = 0.0,
                     window: int = 0,
                     block_tables: Optional[jnp.ndarray] = None,
                     use_pallas: Optional[bool] = None
                     ) -> Tuple[jnp.ndarray, dict]:
    """x (b,1,d); pos (b,) current absolute position. Returns (out, cache').

    Full cache: slot = pos. Sliding window: ring buffer, slot = pos % W.

    block_tables (b, T) selects the paged path: cache leaves are physical
    block stores (n_blocks, B, KVp, hd) shared across sequences, the new
    K/V row is scattered into the sequence's current (exclusively owned)
    block, and attention runs either through the paged Pallas kernel or an
    XLA gather of the sequence's blocks. Incompatible with the sliding
    window ring (the serving runtime falls back to the slot pool there).

    use_pallas (default: REPRO_DECODE_KERNEL=pallas) routes the attention
    itself through the Pallas flash-decoding kernel — per-batch `pos`
    validity masking matches the serving runtime's slot pool, where every
    slot sits at a different position. Full-cache layouts only (the ring
    buffer's modular validity rule is XLA-path only).
    """
    if use_pallas is None:
        use_pallas = os.environ.get("REPRO_DECODE_KERNEL", "") == "pallas"
    b = x.shape[0]
    q = nn.linear(p["wq"], x)                               # (b,1,Hp,hd)
    k = nn.linear(p["wk"], x)                               # (b,1,KVp,hd)
    v = nn.linear(p["wv"], x)
    if rope_theta > 0:
        cos, sin = nn.rope_cos_sin(pos[:, None], dims.head_dim, rope_theta)
        q = nn.apply_rope(q, cos, sin)
        k = nn.apply_rope(k, cos, sin)
    if block_tables is not None:
        assert window == 0, "paged KV does not support the sliding-window ring"
        if "k_scale" in cache:                          # int8 quantized store
            ck, ks = paged_write_quant(cache["k_q8"], cache["k_scale"],
                                       k[:, 0], block_tables, pos)
            cv, vs = paged_write_quant(cache["v_q8"], cache["v_scale"],
                                       v[:, 0], block_tables, pos)
            if use_pallas:
                from repro.kernels import ops
                o = ops.paged_decode_attention_quant(
                    q[:, 0], ck, ks, cv, vs, block_tables, pos)  # (b,Hp,hd)
                o = o.reshape(b, 1, dims.heads_padded * dims.head_dim)
            else:
                o = _grouped_decode_scores(
                    q, paged_gather_dequant(ck, ks, block_tables, x.dtype),
                    paged_gather_dequant(cv, vs, block_tables, x.dtype),
                    pos[:, None], dims, x.dtype)
            return nn.linear(p["wo"], o), {"k_q8": ck, "k_scale": ks,
                                           "v_q8": cv, "v_scale": vs}
        ck = paged_write(cache["k"], k[:, 0], block_tables, pos)
        cv = paged_write(cache["v"], v[:, 0], block_tables, pos)
        if use_pallas:
            from repro.kernels import ops
            o = ops.paged_decode_attention(q[:, 0], ck, cv, block_tables,
                                           pos)  # (b,Hp,hd)
            o = o.reshape(b, 1, dims.heads_padded * dims.head_dim)
        else:
            o = _grouped_decode_scores(q, paged_gather(ck, block_tables),
                                       paged_gather(cv, block_tables),
                                       pos[:, None], dims, x.dtype)
        return nn.linear(p["wo"], o), {"k": ck, "v": cv}
    S = cache["k"].shape[1]
    slot = (pos % S) if window > 0 else pos
    ck = _write_slot(cache["k"], k, slot)
    cv = _write_slot(cache["v"], v, slot)
    ck = lshard(ck, "batch", "kv_seq", None, None)
    cv = lshard(cv, "batch", "kv_seq", None, None)
    if use_pallas and window == 0:
        from repro.kernels import ops
        # pre-grouped head layout == the kernel's (KV, groups) reshape
        o = ops.decode_attention(q[:, 0], ck, cv, pos)      # (b,Hp,hd)
        o = o.reshape(b, 1, dims.heads_padded * dims.head_dim)
        return nn.linear(p["wo"], o), {"k": ck, "v": cv}
    if window > 0:
        # ring slot s holds position pos - ((pos - s) mod S); valid if >= 0
        g = dims.group
        qg = q.reshape(b, 1, dims.kv_padded, g, dims.head_dim)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, ck,
                            preferred_element_type=jnp.float32)
        scores = scores / math.sqrt(dims.head_dim)
        idx = jnp.arange(S)[None, :]                        # (1,S)
        held = pos[:, None] - ((pos[:, None] - idx) % S)
        bias = jnp.where(held >= 0, 0.0, -1e30)[:, None, None, None, :]
        w = jax.nn.softmax(scores + bias, axis=-1).astype(x.dtype)
        o = jnp.einsum("bkgqs,bskd->bqkgd", w, cv)
        o = o.reshape(b, 1, dims.heads_padded * dims.head_dim)
    else:
        o = _grouped_decode_scores(q, ck, cv, pos[:, None], dims, x.dtype)
    out = nn.linear(p["wo"], o)
    return out, {"k": ck, "v": cv}


def attention_decode_chunk(p, x: jnp.ndarray, cache: dict, pos: jnp.ndarray,
                           valid: jnp.ndarray, dims: AttnDims, *,
                           rope_theta: float, block_tables: jnp.ndarray,
                           use_pallas: Optional[bool] = None
                           ) -> Tuple[jnp.ndarray, dict]:
    """Varlen chunked prefill over the paged cache: x (b, C, d) holds up
    to C consecutive prompt tokens per sequence starting at pos (b,), of
    which valid (b,) are real. All C new K/V rows are scattered first
    (invalid rows into the null block), then every query attends the
    gathered dense view with per-query `idx <= pos + j` causality — so
    within-chunk attention needs no separate mask and each position's
    result is bitwise independent of where the chunk starts. Rows past
    `valid` compute garbage the host discards. Paged full-causal caches
    only (the runtime never routes sliding-window configs here)."""
    if use_pallas is None:
        use_pallas = os.environ.get("REPRO_DECODE_KERNEL", "") == "pallas"
    b, C = x.shape[0], x.shape[1]
    q = nn.linear(p["wq"], x)                               # (b,C,Hp,hd)
    k = nn.linear(p["wk"], x)                               # (b,C,KVp,hd)
    v = nn.linear(p["wv"], x)
    positions = pos[:, None] + jnp.arange(C)[None, :]       # (b,C)
    if rope_theta > 0:
        cos, sin = nn.rope_cos_sin(positions, dims.head_dim, rope_theta)
        q = nn.apply_rope(q, cos, sin)
        k = nn.apply_rope(k, cos, sin)
    if "k_scale" in cache:                              # int8 quantized store
        ck, ks = paged_write_chunk_quant(cache["k_q8"], cache["k_scale"],
                                         k, block_tables, pos, valid)
        cv, vs = paged_write_chunk_quant(cache["v_q8"], cache["v_scale"],
                                         v, block_tables, pos, valid)
        if use_pallas:
            from repro.kernels import ops
            o = ops.paged_chunk_attention_quant(q, ck, ks, cv, vs,
                                                block_tables, pos)
            o = o.reshape(b, C, dims.heads_padded * dims.head_dim)
        else:
            o = _grouped_decode_scores(
                q, paged_gather_dequant(ck, ks, block_tables, x.dtype),
                paged_gather_dequant(cv, vs, block_tables, x.dtype),
                positions, dims, x.dtype)
        return nn.linear(p["wo"], o), {"k_q8": ck, "k_scale": ks,
                                       "v_q8": cv, "v_scale": vs}
    ck = paged_write_chunk(cache["k"], k, block_tables, pos, valid)
    cv = paged_write_chunk(cache["v"], v, block_tables, pos, valid)
    if use_pallas:
        from repro.kernels import ops
        o = ops.paged_chunk_attention(q, ck, cv, block_tables,
                                      pos)  # (b,C,Hp,hd)
        o = o.reshape(b, C, dims.heads_padded * dims.head_dim)
    else:
        o = _grouped_decode_scores(q, paged_gather(ck, block_tables),
                                   paged_gather(cv, block_tables),
                                   positions, dims, x.dtype)
    return nn.linear(p["wo"], o), {"k": ck, "v": cv}


# ----------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ----------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, tp: int, dtype):
    m: MLAConfig = cfg.mla
    d = cfg.d_model
    H = cfg.n_heads
    assert H % tp == 0, "MLA head counts in this pool divide the model axis"
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {}
    if m.q_lora_rank:
        p["wq_a"] = nn.init_linear(ks[0], d, m.q_lora_rank, dtype=dtype)
        p["q_norm"] = nn.init_norm(m.q_lora_rank, dtype=dtype)
        p["wq_b"] = nn.init_linear(ks[1], m.q_lora_rank, (H, qk), dtype=dtype)
    else:
        p["wq"] = nn.init_linear(ks[1], d, (H, qk), dtype=dtype)
    p["wkv_a"] = nn.init_linear(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim,
                                dtype=dtype)
    p["kv_norm"] = nn.init_norm(m.kv_lora_rank, dtype=dtype)
    p["wkv_b"] = nn.init_linear(ks[3], m.kv_lora_rank,
                                (H, m.qk_nope_head_dim + m.v_head_dim), dtype=dtype)
    p["wo"] = nn.init_linear(ks[4], H * m.v_head_dim, d, dtype=dtype)
    return p


def mla_specs(cfg: ModelConfig):
    m = cfg.mla
    s = {
        "wkv_a": {"w": ("embed", None)},
        "kv_norm": nn.norm_specs(),
        "wkv_b": {"w": ("kv_lora", "heads", None)},
        "wo": {"w": ("heads", "embed")},
    }
    if m.q_lora_rank:
        s["wq_a"] = {"w": ("embed", "q_lora")}
        s["q_norm"] = nn.norm_specs()
        s["wq_b"] = {"w": ("q_lora", "heads", None)}
    else:
        s["wq"] = {"w": ("embed", "heads", None)}
    return s


def _mla_q(p, x, m: MLAConfig, eps: float):
    if "wq_a" in p:
        qc = nn.apply_norm(p["q_norm"], nn.linear(p["wq_a"], x), eps=eps)
        q = nn.linear(p["wq_b"], qc)
    else:
        q = nn.linear(p["wq"], x)
    return q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]


def mla_forward(p, x: jnp.ndarray, cfg: ModelConfig, *, positions,
                block_q: int = 512) -> jnp.ndarray:
    """Decompressed prefill/train form."""
    m = cfg.mla
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(p, x, m, cfg.norm_eps)
    kv_a = nn.linear(p["wkv_a"], x)
    c_kv = nn.apply_norm(p["kv_norm"], kv_a[..., : m.kv_lora_rank], eps=cfg.norm_eps)
    k_rope = kv_a[..., m.kv_lora_rank:][..., None, :]        # (b,s,1,rope)
    cos, sin = nn.rope_cos_sin(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = nn.apply_rope(q_rope, cos, sin)
    k_rope = nn.apply_rope(k_rope, cos, sin)
    kv = nn.linear(p["wkv_b"], c_kv)                         # (b,s,H,nope+v)
    k_nope = kv[..., : m.qk_nope_head_dim]
    v = kv[..., m.qk_nope_head_dim:]
    H = cfg.n_heads
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (b, s, H, m.qk_rope_head_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q = lshard(q, "batch", None, "heads", None)
    k = lshard(k, "batch", None, "heads", None)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    # pad v's head_dim up to qk dim so blockwise_attention's shapes agree
    o = blockwise_attention(q, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                              (0, k.shape[-1] - v.shape[-1]))),
                            causal=True, block_q=block_q, softmax_scale=scale)
    o = o[..., : m.v_head_dim].reshape(b, s, H * m.v_head_dim)
    o = lshard(o, "batch", None, "heads")
    return nn.linear(p["wo"], o)


def init_mla_cache(batch: int, cache_len: int, cfg: ModelConfig, dtype) -> dict:
    m = cfg.mla
    return {"c_kv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, cache_len, m.qk_rope_head_dim), dtype)}


def mla_cache_specs() -> dict:
    return {"c_kv": ("batch", "kv_seq", None),
            "k_rope": ("batch", "kv_seq", None)}


def mla_decode(p, x: jnp.ndarray, cache: dict, pos: jnp.ndarray,
               cfg: ModelConfig,
               block_tables: Optional[jnp.ndarray] = None,
               valid: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, dict]:
    """Absorbed decode form: scores live in the compressed latent space.

    With block_tables, the compressed latents page exactly like plain KV
    (leaves (n_blocks, B, rank)); scores run against the gathered dense
    view — the latent store is small enough that a dedicated Pallas paged
    kernel is not worth it.

    `valid` selects varlen chunked prefill (paged only): x (b, C, d)
    holds up to C consecutive prompt tokens starting at pos, of which
    valid (b,) are real — the scores einsums are already q-general, so
    the chunk path only changes the per-query positions, the cache write
    (all C rows scattered, invalid ones into the null block) and the
    causal mask. Without it x is (b, 1, d), exactly the PR-2 tick.
    """
    m = cfg.mla
    b, Q = x.shape[0], x.shape[1]
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(p, x, m, cfg.norm_eps)           # (b,Q,H,*)
    kv_a = nn.linear(p["wkv_a"], x)
    c_new = nn.apply_norm(p["kv_norm"], kv_a[..., : m.kv_lora_rank],
                          eps=cfg.norm_eps)
    kr_new = kv_a[..., m.kv_lora_rank:]
    if valid is None:
        positions = pos[:, None]                              # (b,1)
    else:
        positions = pos[:, None] + jnp.arange(Q)[None, :]     # (b,C)
    cos, sin = nn.rope_cos_sin(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = nn.apply_rope(q_rope, cos, sin)
    kr_new = nn.apply_rope(kr_new[..., None, :], cos, sin)[..., 0, :]
    if block_tables is not None:
        if valid is None:
            ckv_blocks = paged_write(cache["c_kv"], c_new[:, 0],
                                     block_tables, pos)
            kr_blocks = paged_write(cache["k_rope"], kr_new[:, 0],
                                    block_tables, pos)
        else:
            ckv_blocks = paged_write_chunk(cache["c_kv"], c_new,
                                           block_tables, pos, valid)
            kr_blocks = paged_write_chunk(cache["k_rope"], kr_new,
                                          block_tables, pos, valid)
        c_kv = paged_gather(ckv_blocks, block_tables)
        k_rope = paged_gather(kr_blocks, block_tables)
        new_cache = {"c_kv": ckv_blocks, "k_rope": kr_blocks}
    else:
        assert valid is None, "chunked prefill is paged-only"
        c_kv = _write_slot(cache["c_kv"], c_new, pos)
        k_rope = _write_slot(cache["k_rope"], kr_new, pos)
        c_kv = lshard(c_kv, "batch", "kv_seq", None)
        k_rope = lshard(k_rope, "batch", "kv_seq", None)
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
    wkv_b = p["wkv_b"]["w"].astype(x.dtype)                  # (r,H,nope+v)
    w_k = wkv_b[..., : m.qk_nope_head_dim]                   # (r,H,nope)
    w_v = wkv_b[..., m.qk_nope_head_dim:]                    # (r,H,v)
    # absorb: q_c (b,Q,H,r)
    q_c = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_k)
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_c, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope,
                           preferred_element_type=jnp.float32))
    scores = scores / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    S = c_kv.shape[1]
    live = jnp.arange(S)[None, None, :] <= positions[:, :, None]  # (b,Q,S)
    scores = scores + jnp.where(live, 0.0, -1e30)[:, None, :, :]
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", w, c_kv)            # (b,Q,H,r)
    o = jnp.einsum("bqhr,rhd->bqhd", o_lat, w_v)
    o = o.reshape(b, Q, H * m.v_head_dim)
    out = nn.linear(p["wo"], o)
    return out, new_cache
