"""Training launcher.

On the CPU container this trains REDUCED/tiny configs for real (the
paper-repro path); on a TPU fleet the same entrypoint drives full configs
over the production mesh (the dry-run proves those lower + compile).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b-reduced \
        --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time



def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mathstral-tiny")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-digits", type=int, default=6)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.checkpoint import save_checkpoint
    from repro.configs import get_config
    from repro.data import LMDataPipeline, PipelineConfig, VOCAB
    from repro.models import build_model
    from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                             linear_warmup_cosine)

    cfg = get_config(args.arch)
    if cfg.vocab_size != VOCAB:
        cfg = dataclasses.replace(cfg, vocab_size=VOCAB)
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)
    pipe = LMDataPipeline(PipelineConfig(global_batch=args.batch,
                                         seq_len=args.seq, seed=args.seed,
                                         max_digits=args.max_digits))

    @jax.jit
    def step_fn(params, opt, batch, lr):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt = adamw_update(params, grads, opt, lr=lr,
                                   weight_decay=0.01)
        return params, opt, loss, gnorm

    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        lr = linear_warmup_cosine(jnp.float32(step), base_lr=args.lr,
                                  warmup_steps=args.warmup,
                                  total_steps=args.steps)
        params, opt, loss, gnorm = step_fn(params, opt, batch, lr)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(loss):.4f} "
                  f"gnorm {float(gnorm):.3f} "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)")
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": params},
                        step=args.steps, extra={"arch": args.arch})
        print("saved", args.ckpt)
    return params, model


if __name__ == "__main__":
    main()
