"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. The dry-run entrypoint sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
tests and benches see the single real CPU device.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devs)}; run via "
            "repro.launch.dryrun which sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Tiny mesh for CI-scale dry-run tests (requires >=4 host devices)."""
    import jax

    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
