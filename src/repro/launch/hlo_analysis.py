"""Static analysis of optimized HLO for the roofline deliverable.

XLA's `compiled.cost_analysis()` counts while-loop bodies ONCE, which makes
scan-over-layers models look 24-72x cheaper than they are. This module
re-derives per-device cost from the HLO text with proper loop accounting:

  * parses every computation + a per-computation symbol table (op -> shape)
  * builds the call graph (calls= / to_apply= / body= / condition=) and
    propagates multipliers from `backend_config known_trip_count`
  * FLOPs: 2 * prod(result) * prod(contracting dims) per `dot`
    (+ convolutions if any), summed over reachable computations x multiplier
  * bytes: per-op (operands + result), counted at fusion boundaries only
    (fusion internals are register/VMEM traffic, not HBM)
  * collective bytes per kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), loop-aware; all-reduce counted 2x
    (reduce-scatter + all-gather phases on a ring)

These are per-PARTITION numbers (the module is already SPMD-partitioned).

The parse + call-graph layer lives in :mod:`repro.analysis.callgraph`
(the static auditor shares it); this module re-exports it for
back-compat and keeps the cost model.
"""
from __future__ import annotations

import re
from typing import Dict

# Re-exported for back-compat: the parse/call-graph layer moved to
# repro.analysis.callgraph so the analysis package has no launch dep.
from repro.analysis.callgraph import (  # noqa: F401
    DTYPE_BYTES, HOST_TRANSFER_OPS, CallGraph, Computation, Op,
    _HOST_CALLBACK_RE, _SHAPE_RE, _one_shape_bytes, _parse_trip_count,
    build_call_graph, find_host_ops, parse_hlo, shape_info)

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _dot_flops(op: Op, comp: Computation) -> int:
    rbytes, rdims = shape_info(op.result_shape)
    n_out = 1
    for d in rdims:
        n_out *= d
    lhs = comp.shapes.get(op.operands[0], "") if op.operands else ""
    _, ldims = shape_info(lhs)
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", op.attrs)
    contract = 1
    if m and ldims:
        for idx in m.group(1).split(","):
            if idx:
                i = int(idx)
                if i < len(ldims):
                    contract *= ldims[i]
    return 2 * n_out * contract


_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "while", "conditional", "call", "custom-call",
                   "after-all", "partition-id", "replica-id"}


_LAYOUT_OPS = {"parameter", "constant", "convert", "bitcast", "copy",
               "transpose", "reshape", "broadcast"}
def _is_passthrough(callee: "Computation") -> bool:
    """Layout/slice/dequant-only fusion: its output is a re-coded view of
    its params (a TPU compiler folds it into the consumer's read)."""
    return all(o.opcode in _LAYOUT_OPS or o.opcode in _WINDOW_OPS
               or o.opcode == "multiply" for o in callee.ops)


def _passthrough_bytes(callee: "Computation") -> int:
    """True HBM bytes behind a passthrough fusion: its params at their
    stored dtype/window."""
    total = 0
    for pname, pshape in callee.params.items():
        psize = shape_info(pshape)[0]
        consumers = [o for o in callee.ops if pname in o.operands]
        if consumers and all(o.opcode in _WINDOW_OPS for o in consumers):
            psize = max(o.result_bytes for o in consumers)
        total += psize
    return total


def _fusion_bytes(op: "Op", comp: "Computation", comps) -> int:
    """HBM traffic of a fusion: operands + result, with in-place
    dynamic-update-slice roots charged at UPDATE-window size (XLA aliases
    the big buffer; without this, a scanned cache update is billed the
    whole multi-GB cache every iteration)."""
    m = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
    callee = comps.get(m.group(1)) if m else None
    rb = op.result_bytes
    if callee is None or not callee.ops:
        opb = sum(shape_info(comp.shapes.get(o, ""))[0] for o in op.operands)
        return opb + rb
    # Pure dtype/layout/slice/dequant fusions are CPU-backend artifacts (no
    # native bf16/int8 matmul on CPU => f32 weight copies): the consumer op
    # charges the SOURCE bytes (see _passthrough_bytes) — charge zero here.
    if _is_passthrough(callee):
        return 0
    root = next((o for o in callee.ops if o.is_root), callee.ops[-1])
    # follow convert/bitcast/copy chains: a DUS wrapped in dtype converts is
    # still an in-place window update on TPU
    by_name = {o.name: o for o in callee.ops}
    hops = 0
    while (root.opcode in ("convert", "bitcast", "copy") and root.operands
           and root.operands[0] in by_name and hops < 8):
        root = by_name[root.operands[0]]
        hops += 1
    if root.opcode in _UPDATE_OPS:
        upd = (shape_info(callee.shapes.get(root.operands[1], ""))[0]
               if len(root.operands) > 1 else 0)
        # 2x window + any small non-aliased operands
        small = sum(shape_info(comp.shapes.get(o, ""))[0]
                    for o in op.operands
                    if shape_info(comp.shapes.get(o, ""))[0] != rb)
        return 2 * upd + min(small, rb)
    # general fusion: charge each callee parameter at its consumed window
    # (a param only read through dynamic-slice/gather costs the window, not
    # the whole stacked-layers buffer), plus the result write.
    total = rb
    pnames = list(callee.params)
    for idx, pname in enumerate(pnames):
        psize = shape_info(callee.params[pname])[0]
        consumers = [o for o in callee.ops if pname in o.operands]
        if consumers and all(o.opcode in _WINDOW_OPS for o in consumers):
            psize = max(o.result_bytes for o in consumers)
        total += psize
    return total


# ops that touch only their RESULT-sized window of the operand (counting the
# full operand would charge a scan body for the whole stacked-layer tensor
# on every iteration)
_WINDOW_OPS = {"dynamic-slice", "slice", "gather"}
# in-place update ops: traffic ~ 2x the update slice, not the full buffer
_UPDATE_OPS = {"dynamic-update-slice", "scatter", "select-and-scatter"}

# elementwise/layout ops that a TPU compiler fuses into neighbours; counted
# in bytes_upper but excluded from the fusion-adjusted bytes estimate
_FUSIBLE_OPS = {"add", "subtract", "multiply", "divide", "maximum",
                "minimum", "exponential", "tanh", "negate", "abs", "power",
                "rsqrt", "sqrt", "log", "logistic", "compare", "select",
                "and", "or", "not", "convert", "broadcast", "iota",
                "reshape", "transpose", "reverse", "clamp", "sign",
                "floor", "ceil", "round-nearest-even", "pad",
                "exponential-minus-one", "log-plus-one", "remainder",
                "shift-right-logical", "shift-left", "xor", "map",
                "reduce-precision", "is-finite", "atan2", "cosine", "sine",
                "tan", "erf", "real", "imag", "stochastic-convert",
                "bitcast-convert", "copy", "concatenate"}


def analyze(text: str) -> Dict:
    comps = parse_hlo(text)
    graph = build_call_graph(comps)
    if graph.entry is None:
        return {"flops": 0, "bytes": 0, "collectives": {}}
    mult, fusion_ctx, order = graph.mult, graph.fusion_ctx, graph.order

    flops = 0.0
    transcend = 0.0
    bytes_upper = 0.0       # every non-fused op: operands + result
    bytes_major = 0.0       # fusion-adjusted: TPU-fusible elementwise skipped
    coll = {c: 0.0 for c in COLLECTIVES}
    coll_counts = {c: 0 for c in COLLECTIVES}
    for cname in order:
        comp = comps.get(cname)
        if comp is None or mult[cname] == 0:
            continue
        k = mult[cname]
        in_fusion = fusion_ctx[cname]
        # passthrough-fusion source sizes (dequant/layout/slice views):
        # consumers charge these instead of the materialized f32 copies
        passthrough: Dict[str, int] = {}
        for op in comp.ops:
            if op.opcode == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
                callee = comps.get(m.group(1)) if m else None
                if callee is not None and callee.ops and \
                        _is_passthrough(callee):
                    passthrough[op.name] = _passthrough_bytes(callee)

        def operand_bytes(o: str) -> int:
            if o in passthrough:
                return passthrough[o]
            return shape_info(comp.shapes.get(o, ""))[0]

        for op in comp.ops:
            if op.opcode == "dot":
                flops += k * _dot_flops(op, comp)
            base = op.opcode.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES and not op.opcode.endswith("-done"):
                opb = sum(shape_info(comp.shapes.get(o, ""))[0]
                          for o in op.operands)
                rb = op.result_bytes
                size = max(opb, rb)
                if base == "all-reduce":
                    size *= 2          # ring RS + AG phases
                coll[base] += k * size
                coll_counts[base] += 1
            if not in_fusion and op.opcode not in _SKIP_BYTES_OPS:
                rb = op.result_bytes
                if op.opcode in _WINDOW_OPS:
                    b = 2 * rb                       # read window + write
                elif op.opcode in _UPDATE_OPS:
                    # update operand (second arg) read + written window
                    upd = (shape_info(comp.shapes.get(op.operands[1], ""))[0]
                           if len(op.operands) > 1 else rb)
                    b = 2 * upd
                elif op.opcode == "fusion":
                    b = _fusion_bytes(op, comp, comps)
                else:
                    opb = sum(operand_bytes(o) for o in op.operands)
                    b = opb + rb
                bytes_upper += k * b
                if op.opcode not in _FUSIBLE_OPS:
                    bytes_major += k * b
            if op.opcode in ("exponential", "tanh", "log", "rsqrt", "power",
                             "logistic") and not in_fusion:
                transcend += k * max(op.result_bytes // 4, 0)
    return {
        "flops": flops,
        "bytes": bytes_major,
        "bytes_upper": bytes_upper,
        "transcendentals": transcend,
        "collectives": coll,
        "collective_counts": coll_counts,
        "collective_bytes_total": sum(coll.values()),
        "n_computations": len(comps),
    }
