"""Adaptive serving launcher (end-to-end driver, deliverable b).

Trains a small LM on the arithmetic task suite, trains the difficulty
probe on its own hidden states, then serves batches of queries through the
AdaptiveScheduler — the paper's full loop — and prints the adaptive-vs-
uniform comparison.

    PYTHONPATH=src python -m repro.launch.serve --budget 4 --n-queries 64
"""
from __future__ import annotations

import argparse

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--n-train-queries", type=int, default=256)
    ap.add_argument("--n-queries", type=int, default=64)
    ap.add_argument("--budget", type=float, default=4.0)
    ap.add_argument("--b-max", type=int, default=16)
    ap.add_argument("--samples-for-labels", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax

    from repro.core import AdaptivePolicy
    from repro.core.difficulty import train_mlp_probe
    from repro.core.marginal import empirical_lambda
    from repro.data.tasks import ArithTaskGen
    from repro.launch import train as train_mod
    from repro.rewards import VerifierReward
    from repro.serving import AdaptiveScheduler, ServingEngine

    print("== 1. train the base LM on the task suite ==")
    params, model = train_mod.main([
        "--arch", "mathstral-tiny", "--steps", str(args.train_steps),
        "--batch", "32", "--seq", "64", "--seed", str(args.seed)])

    gen = ArithTaskGen(max_digits=6, seed=args.seed + 1)
    engine = ServingEngine(model, params, max_new=8, temperature=1.0)
    verifier = VerifierReward(lambda q, toks: q.check(list(np.asarray(toks))))

    def prompts_of(problems, width=None):
        rows = [p.prompt_tokens() for p in problems]
        w = width or max(len(r) for r in rows)
        return np.asarray([[0] * (w - len(r)) + r for r in rows], np.int32)

    print("== 2. label training queries with empirical λ ==")
    train_q = gen.sample(args.n_train_queries)
    tp = prompts_of(train_q, width=16)
    res = engine.generate(tp, n_samples=args.samples_for_labels,
                          seed=args.seed + 2)
    succ = np.zeros((len(train_q), args.samples_for_labels))
    for i, q in enumerate(train_q):
        for j in range(args.samples_for_labels):
            succ[i, j] = q.check(
                list(res.tokens[i * args.samples_for_labels + j]))
    lam = empirical_lambda(succ)
    print(f"   λ: mean={lam.mean():.3f}  zero-frac={(lam == 0).mean():.2f}")

    print("== 3. train the difficulty probe on prefill hidden states ==")
    feats = engine.probe_features(tp)
    probe, info = train_mlp_probe(jax.random.PRNGKey(args.seed + 3), feats,
                                  lam, kind="bce", steps=800)
    print(f"   probe val loss {info['val_loss']:.4f}")

    policy = AdaptivePolicy(probe_params=probe, kind="bce", b_max=args.b_max)
    sched = AdaptiveScheduler(engine, policy, verifier, seed=args.seed + 4)

    print("== 4. serve a fresh batch adaptively vs uniformly ==")
    test_q = gen.sample(args.n_queries)
    prompts = prompts_of(test_q, width=16)
    out = sched.serve_batch(test_q, prompts, avg_budget=args.budget)
    adaptive_acc = (out.rewards > 0).mean()

    # uniform baseline at the same total sample count
    k = max(1, int(round(out.total_samples / args.n_queries)))
    resu = engine.generate(prompts, n_samples=k, seed=args.seed + 5)
    uni = np.zeros(args.n_queries)
    for i, q in enumerate(test_q):
        uni[i] = max(verifier(q, list(resu.tokens[i * k:(i + 1) * k])))
    print(f"   adaptive: acc={adaptive_acc:.3f} "
          f"samples={out.total_samples} budgets={np.bincount(out.budgets)}")
    print(f"   uniform : acc={(uni > 0).mean():.3f} "
          f"samples={k * args.n_queries}")
    return adaptive_acc, (uni > 0).mean()


if __name__ == "__main__":
    main()
