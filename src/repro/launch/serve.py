"""Adaptive serving launcher (end-to-end driver, deliverable b).

Trains small LM(s) on the arithmetic task suite, trains the difficulty /
preference probe on prefill hidden states, then serves batches of queries
through the procedure-centric runtime — the paper's full loop — and
prints the adaptive-vs-baseline comparison.

    --procedure bestofk   (default) AdaptiveScheduler best-of-k vs the
                          uniform baseline at equal samples (paper §4.1)
    --procedure route     weak/strong routing (paper §4.2): a weak LM
                          (under-trained) and a strong LM share one paged
                          pool; a kind="pref" probe on the weak model's
                          prefill hidden states routes the top
                          --strong-frac of queries to the strong model.
                          Prints routed vs weak-only / strong-only /
                          random-routing accuracy.
    --procedure single    the uniform b=1 baseline through the runtime's
                          Single procedure (sanity floor)

    PYTHONPATH=src python -m repro.launch.serve --budget 4 --n-queries 64
    PYTHONPATH=src python -m repro.launch.serve --procedure route \
        --strong-frac 0.5 --n-queries 64
"""
from __future__ import annotations

import argparse

import numpy as np


def _prompts_of(problems, width=None):
    rows = [p.prompt_tokens() for p in problems]
    w = width or max(len(r) for r in rows)
    return np.asarray([[0] * (w - len(r)) + r for r in rows], np.int32)


def _success_rates(engine, queries, prompts, n_samples, seed):
    res = engine.generate(prompts, n_samples=n_samples, seed=seed)
    succ = np.zeros((len(queries), n_samples))
    for i, q in enumerate(queries):
        for j in range(n_samples):
            succ[i, j] = q.check(list(res.tokens[i * n_samples + j]))
    return succ


def _serve_route(args, gen, verifier):
    """Weak/strong routing: two models, one pool, one Route procedure."""
    import jax

    from repro.core.difficulty import train_mlp_probe
    from repro.core.routing import preference_predictor
    from repro.launch import train as train_mod
    from repro.serving import (ContinuousBatchingRuntime, Route,
                               ServingEngine)

    print("== 1. train the WEAK and STRONG LMs on the task suite ==")
    weak_steps = max(20, args.train_steps // 4)
    w_params, w_model = train_mod.main([
        "--arch", "mathstral-tiny", "--steps", str(weak_steps),
        "--batch", "32", "--seq", "64", "--seed", str(args.seed)])
    s_params, s_model = train_mod.main([
        "--arch", "mathstral-tiny", "--steps", str(args.train_steps),
        "--batch", "32", "--seq", "64", "--seed", str(args.seed + 1)])
    w_engine = ServingEngine(w_model, w_params, max_new=8, temperature=1.0)
    s_engine = ServingEngine(s_model, s_params, max_new=8, temperature=1.0)

    print("== 2. label preference p(strong beats weak) on train queries ==")
    train_q = gen.sample(args.n_train_queries)
    tp = _prompts_of(train_q, width=16)
    k = args.samples_for_labels
    lam_w = _success_rates(w_engine, train_q, tp, k, args.seed + 2).mean(1)
    lam_s = _success_rates(s_engine, train_q, tp, k, args.seed + 3).mean(1)
    pref = np.clip(0.5 + (lam_s - lam_w) / 2.0, 0.0, 1.0)
    print(f"   λ_weak={lam_w.mean():.3f} λ_strong={lam_s.mean():.3f} "
          f"pref>0.5 frac={(pref > 0.5).mean():.2f}")

    print("== 3. train the preference probe on WEAK prefill hiddens ==")
    feats = w_engine.probe_features(tp)
    probe, info = train_mlp_probe(jax.random.PRNGKey(args.seed + 4), feats,
                                  pref, kind="pref", steps=800)
    print(f"   probe val loss {info['val_loss']:.4f}")
    predictor = preference_predictor(probe, kind="pref")

    scores = [predictor(None, h) for h in feats]
    thr = Route.calibrate_threshold(scores, args.strong_frac)
    print(f"   threshold at strong_frac={args.strong_frac}: {thr:.4f}")

    print("== 4. serve a fresh stream through Route (shared paged pool) ==")
    test_q = gen.sample(args.n_queries)
    prompts = _prompts_of(test_q, width=16)
    rt = ContinuousBatchingRuntime(
        w_model, w_params, n_slots=8, max_len=16 + 8 + 1, max_new=8,
        temperature=1.0, seed=args.seed + 5, pool="paged",
        reward_fn=verifier)
    rt.register_model("strong", s_model, s_params)
    proc = Route(weak="default", strong="strong", predictor=predictor,
                 threshold=thr)
    ids = [rt.submit(prompts[i], query=test_q[i], procedure=proc)
           for i in range(args.n_queries)]
    rt.drain()
    routed = np.asarray([rt.result(i).reward > 0 for i in ids])
    frac = np.mean([rt.result(i).proc["route"] == "strong" for i in ids])

    # baselines at the same test stream
    acc_w = (_success_rates(w_engine, test_q, prompts, 1,
                            args.seed + 6).mean(1) > 0).mean()
    acc_s = (_success_rates(s_engine, test_q, prompts, 1,
                            args.seed + 7).mean(1) > 0).mean()
    rand = frac * acc_s + (1 - frac) * acc_w    # expected random routing
    pm = {m: mm.summary() for m, mm in rt.metrics.per_model.items()}
    print(f"   routed  : acc={routed.mean():.3f} strong_frac={frac:.2f} "
          f"strong_tokens={pm.get('strong', {}).get('total_tokens', 0)}")
    print(f"   weak    : acc={acc_w:.3f}   strong: acc={acc_s:.3f}   "
          f"random@{frac:.2f}: acc={rand:.3f}")
    return float(routed.mean()), float(rand)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--n-train-queries", type=int, default=256)
    ap.add_argument("--n-queries", type=int, default=64)
    ap.add_argument("--budget", type=float, default=4.0)
    ap.add_argument("--b-max", type=int, default=16)
    ap.add_argument("--samples-for-labels", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--procedure", choices=("bestofk", "route", "single"),
                    default="bestofk",
                    help="serving procedure: adaptive best-of-k (paper "
                         "§4.1), weak/strong routing (§4.2), or the "
                         "uniform b=1 Single baseline")
    ap.add_argument("--strong-frac", type=float, default=0.5,
                    help="route: fraction of queries targeted at the "
                         "strong model (threshold calibration)")
    args = ap.parse_args(argv)

    import jax

    from repro.core import AdaptivePolicy
    from repro.core.difficulty import train_mlp_probe
    from repro.core.marginal import empirical_lambda
    from repro.data.tasks import ArithTaskGen
    from repro.launch import train as train_mod
    from repro.rewards import VerifierReward
    from repro.serving import (AdaptiveScheduler, ContinuousBatchingRuntime,
                               ServingEngine, Single)

    gen = ArithTaskGen(max_digits=6, seed=args.seed + 1)
    verifier = VerifierReward(lambda q, toks: q.check(list(np.asarray(toks))))

    if args.procedure == "route":
        return _serve_route(args, gen, verifier)

    print("== 1. train the base LM on the task suite ==")
    params, model = train_mod.main([
        "--arch", "mathstral-tiny", "--steps", str(args.train_steps),
        "--batch", "32", "--seq", "64", "--seed", str(args.seed)])
    engine = ServingEngine(model, params, max_new=8, temperature=1.0)

    if args.procedure == "single":
        print("== 2. serve uniformly at b=1 through the Single procedure ==")
        test_q = gen.sample(args.n_queries)
        prompts = _prompts_of(test_q, width=16)
        reward_fn = lambda q, rows: [float(q.check(list(np.asarray(r))))
                                     for r in rows]
        rt = ContinuousBatchingRuntime(
            model, params, n_slots=8, max_len=16 + 8 + 1, max_new=8,
            temperature=1.0, seed=args.seed + 5, reward_fn=reward_fn)
        ids = [rt.submit(prompts[i], query=test_q[i], procedure=Single())
               for i in range(args.n_queries)]
        rt.drain()
        acc = np.mean([rt.result(i).reward > 0 for i in ids])
        print(f"   single(b=1): acc={acc:.3f} "
              f"tokens={rt.metrics.decode_tokens}")
        return float(acc), float(acc)

    print("== 2. label training queries with empirical λ ==")
    train_q = gen.sample(args.n_train_queries)
    tp = _prompts_of(train_q, width=16)
    succ = _success_rates(engine, train_q, tp, args.samples_for_labels,
                          args.seed + 2)
    lam = empirical_lambda(succ)
    print(f"   λ: mean={lam.mean():.3f}  zero-frac={(lam == 0).mean():.2f}")

    print("== 3. train the difficulty probe on prefill hidden states ==")
    feats = engine.probe_features(tp)
    probe, info = train_mlp_probe(jax.random.PRNGKey(args.seed + 3), feats,
                                  lam, kind="bce", steps=800)
    print(f"   probe val loss {info['val_loss']:.4f}")

    policy = AdaptivePolicy(probe_params=probe, kind="bce", b_max=args.b_max)
    sched = AdaptiveScheduler(engine, policy, verifier, seed=args.seed + 4)

    print("== 4. serve a fresh batch adaptively vs uniformly ==")
    test_q = gen.sample(args.n_queries)
    prompts = _prompts_of(test_q, width=16)
    out = sched.serve_batch(test_q, prompts, avg_budget=args.budget)
    adaptive_acc = (out.rewards > 0).mean()

    # uniform baseline at the same total sample count
    k = max(1, int(round(out.total_samples / args.n_queries)))
    resu = engine.generate(prompts, n_samples=k, seed=args.seed + 5)
    uni = np.zeros(args.n_queries)
    for i, q in enumerate(test_q):
        uni[i] = max(verifier(q, list(resu.tokens[i * k:(i + 1) * k])))
    print(f"   adaptive: acc={adaptive_acc:.3f} "
          f"samples={out.total_samples} budgets={np.bincount(out.budgets)}")
    print(f"   uniform : acc={(uni > 0).mean():.3f} "
          f"samples={k * args.n_queries}")
    return adaptive_acc, (uni > 0).mean()


if __name__ == "__main__":
    main()
