"""Per-op attribution of collective/memory bytes from a stored .hlo.gz —
the 'profiler' of the dry-run perf loop.

    PYTHONPATH=src python -m repro.launch.attribute <artifact-stem> [--mem]
"""
from __future__ import annotations

import gzip
import re
import sys
from collections import defaultdict
from pathlib import Path

from repro.launch.hlo_analysis import (COLLECTIVES,
                                       _FUSIBLE_OPS, _SKIP_BYTES_OPS,
                                       _UPDATE_OPS, _WINDOW_OPS,
                                       _fusion_bytes, _parse_trip_count,
                                       parse_hlo, shape_info)

ARTIFACTS = Path(__file__).resolve().parents[3] / "experiments" / "artifacts"


def multipliers(comps):
    entry = next(c for c in comps.values() if c.is_entry)
    mult = defaultdict(float)
    fus = defaultdict(bool)
    mult[entry.name] = 1.0
    order, seen, i = [entry.name], {entry.name}, 0
    while i < len(order):
        cn = order[i]
        i += 1
        comp = comps.get(cn)
        if comp is None:
            continue
        for op in comp.ops:
            callees = []
            if op.opcode == "while":
                t = _parse_trip_count(op.attrs)
                for kw in ("body", "condition"):
                    m = re.search(kw + r"=%?([\w\.\-]+)", op.attrs)
                    if m:
                        callees.append((m.group(1), float(t), False))
            elif op.opcode == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
                if m:
                    callees.append((m.group(1), 1.0, True))
            else:
                for kw in ("calls", "to_apply", "body", "condition"):
                    m = re.search(kw + r"=%?([\w\.\-]+)", op.attrs)
                    if m:
                        callees.append((m.group(1), 1.0, fus[cn]))
            for c, k, f in callees:
                mult[c] += mult[cn] * k
                fus[c] = fus[c] or f or (op.opcode == "fusion")
                if c not in seen:
                    seen.add(c)
                    order.append(c)
    return mult, fus, order


def attribute(stem: str, top: int = 15, mem: bool = False):
    hlo = gzip.open(ARTIFACTS / f"{stem}.hlo.gz", "rt").read()
    comps = parse_hlo(hlo)
    mult, fus, order = multipliers(comps)
    rows = []
    for cn in order:
        comp = comps.get(cn)
        if comp is None:
            continue
        k = mult[cn]
        for op in comp.ops:
            base = op.opcode.replace("-start", "").replace("-done", "")
            if not mem:
                if base in COLLECTIVES and not op.opcode.endswith("-done"):
                    opb = sum(shape_info(comp.shapes.get(o, ""))[0]
                              for o in op.operands)
                    size = max(opb, op.result_bytes)
                    if base == "all-reduce":
                        size *= 2
                    meta = re.search(r'op_name="([^"]*)"', op.attrs)
                    rows.append((k * size, k, base, op.result_shape[:48],
                                 (meta.group(1) if meta else "")[:90]))
            else:
                if fus[cn] or op.opcode in _SKIP_BYTES_OPS:
                    continue
                rb = op.result_bytes
                if op.opcode in _WINDOW_OPS:
                    b = 2 * rb
                elif op.opcode in _UPDATE_OPS:
                    upd = (shape_info(comp.shapes.get(op.operands[1], ""))[0]
                           if len(op.operands) > 1 else rb)
                    b = 2 * upd
                elif op.opcode == "fusion":
                    b = _fusion_bytes(op, comp, comps)
                else:
                    b = rb + sum(shape_info(comp.shapes.get(o, ""))[0]
                                 for o in op.operands)
                if op.opcode in _FUSIBLE_OPS:
                    continue
                meta = re.search(r'op_name="([^"]*)"', op.attrs)
                rows.append((k * b, k, op.opcode, op.result_shape[:48],
                             (meta.group(1) if meta else "")[:90]))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"total {'mem' if mem else 'collective'} bytes: {total:.3e}")
    for r in rows[:top]:
        print(f"{r[0]:10.3e}  x{r[1]:<4.0f} {r[2]:<18s} {r[3]:<48s} {r[4]}")


if __name__ == "__main__":
    stem = sys.argv[1]
    attribute(stem, mem="--mem" in sys.argv)
