import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))
# ^ MUST precede any jax import (device count locks on first init).

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x input shape x mesh) this lowers + compiles the
appropriate step (train_step / prefill_step / serve_step) against
ShapeDtypeStruct inputs — no allocation — and records:

  * compiled.memory_analysis()   (per-device bytes: does it fit 16 GB?)
  * compiled.cost_analysis()     (per-device HLO FLOPs / bytes accessed)
  * per-collective byte sums parsed from the optimized HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), with while-loop bodies multiplied by the layer
    scan trip count

Artifacts land in experiments/artifacts/<arch>__<shape>__<mesh>.json and
feed benchmarks/bench_roofline.py + EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import numpy as np

ARTIFACTS = Path(__file__).resolve().parents[3] / "experiments" / "artifacts"

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "c64": 8,
               "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128,4096]{...}' -> byte count (0 for tuples/tokens)."""
    m = re.match(r"([a-z0-9]+)\[([0-9,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES[dt]


def parse_collective_bytes(hlo: str, loop_mult: dict) -> dict:
    """Sum output bytes of collective ops in the optimized HLO.

    loop_mult: {computation_name_substring: multiplier} for while bodies
    (the layer scan); collectives outside ENTRY matched by none default to
    multiplier 1.
    """
    out = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    current_comp = ""
    for line in hlo.splitlines():
        if line and not line.startswith(" ") and "{" in line:
            mh = re.search(r"(?:ENTRY\s+)?%?([\w\.\-]+)", line)
            if mh:
                current_comp = mh.group(1)
        for coll in COLLECTIVES:
            # e.g.  %ag = bf16[2,64]{1,0} all-gather(...)
            m = re.search(r"=\s+(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
                          + coll + r"(?:-start|-done)?\(", line)
            if m:
                sh = m.group(1)
                if sh.startswith("("):
                    byt = sum(_shape_bytes(s.strip())
                              for s in sh[1:-1].split(","))
                else:
                    byt = _shape_bytes(sh)
                mult = 1
                for frag, mul in loop_mult.items():
                    if frag in current_comp:
                        mult = mul
                        break
                out[coll] += byt * mult
                counts[coll] += 1
    out["_counts"] = counts
    return out


# §Perf A preset: ZeRO-3 param sharding + full data-parallel batch.
# -69% collective bytes vs TP+SP for qwen2.5-32b train_4k (EXPERIMENTS.md).
FSDP_RULES = {"batch": ("data", "model"), "seq_sp": None,
              "mlp": ("data", "model"), "vocab": ("data", "model"),
              "heads": "data", "kv_heads": None}


def batch_spec(gb: int, mesh, extra=()):
    """Shard batch over (pod,data) when divisible, else replicate."""
    from jax.sharding import PartitionSpec as P
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n = int(np.prod([mesh.shape[a] for a in axes]))
    lead = axes if (gb % n == 0 and gb >= n) else None
    return P(lead, *extra)


def build_inputs(cfg, shape, mesh, model):
    """ShapeDtypeStructs + NamedShardings for the step inputs."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    sds = jax.ShapeDtypeStruct
    gb, seq = shape.global_batch, shape.seq_len
    tok_spec = batch_spec(gb, mesh, (None,))
    ns = lambda spec: NamedSharding(mesh, spec)

    if shape.kind in ("train", "prefill"):
        text = seq
        batch = {}
        if cfg.family == "vlm":
            text = seq - cfg.encoder.seq_len
            batch["patches"] = (sds((gb, cfg.encoder.seq_len, cfg.d_model),
                                    jnp.bfloat16), ns(tok_spec))
        if cfg.family == "audio":
            batch["frames"] = (sds((gb, cfg.encoder.seq_len, cfg.d_model),
                                   jnp.bfloat16), ns(tok_spec))
        batch["tokens"] = (sds((gb, text), jnp.int32), ns(tok_spec))
        if shape.kind == "train":
            batch["labels"] = (sds((gb, text), jnp.int32), ns(tok_spec))
        return batch
    # decode: token, cache, pos
    from repro.sharding import current_rules, logical_spec

    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(gb, seq))
    _, rules = current_rules()
    cache_specs = model.cache_specs()

    # resolve cache shardings leaf-wise (guarding divisibility per dim)
    flat_s, tdef = jax.tree.flatten(cache_shapes)
    flat_n = jax.tree.flatten(
        cache_specs, is_leaf=lambda t: isinstance(t, tuple) or t is None)[0]
    axes_b = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nb = int(np.prod([mesh.shape[a] for a in axes_b]))
    out = []
    for sh, names in zip(flat_s, flat_n):
        names = list(names if names is not None else [None] * len(sh.shape))
        if gb % nb != 0 or gb < nb:
            names = [None if n == "batch" else n for n in names]
        # guard divisibility for each named dim
        spec_names = []
        for dim, n in zip(sh.shape, names):
            if n is None:
                spec_names.append(None)
                continue
            ax = rules.get(n)
            size = (np.prod([mesh.shape[a] for a in (
                (ax,) if isinstance(ax, str) else (ax or ()))])
                if ax else 1)
            spec_names.append(n if size and dim % int(size) == 0 else None)
        out.append((sh, NamedSharding(mesh, logical_spec(spec_names, rules))))
    cache = jax.tree.unflatten(tdef, out)
    return {
        "token": (sds((gb, 1), jnp.int32), ns(batch_spec(gb, mesh, (None,)))),
        "cache": cache,
        "pos": (sds((gb,), jnp.int32), ns(batch_spec(gb, mesh))),
    }


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: Path = ARTIFACTS, block_q: int = 512,
            tag: str = "baseline", extra_cfg=None,
            extra_rules=None) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import SHAPES_BY_NAME, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import (make_prefill_step, make_serve_step,
                                    make_train_step)
    from repro.models import build_model
    from repro.optim import adamw_init
    from repro.sharding import axis_rules, default_rules, logical_spec

    t0 = time.time()
    cfg = get_config(arch)
    if extra_cfg:
        import dataclasses
        cfg = dataclasses.replace(cfg, **extra_cfg)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    tp = mesh.shape["model"]
    rules = default_rules(cfg, mesh)
    if extra_rules:
        rules.update(extra_rules)
    model = build_model(cfg, tp=tp, remat=(shape.kind == "train"),
                        block_q=block_q)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "tag": tag, "ok": False}

    with axis_rules(mesh, rules):
        param_shapes = model.param_shapes()
        spec_tree = model.specs()
        p_shard = jax.tree.map(
            lambda names: NamedSharding(mesh, logical_spec(names, rules)),
            spec_tree, is_leaf=lambda t: isinstance(t, tuple) or t is None)

        if shape.kind == "train":
            opt_shapes = jax.eval_shape(adamw_init, param_shapes)
            o_shard = type(opt_shapes)(
                step=NamedSharding(mesh, P()),
                m=p_shard, v=jax.tree.map(lambda s: s, p_shard))
            batch = build_inputs(cfg, shape, mesh, model)
            b_sds = {k: v[0] for k, v in batch.items()}
            b_shard = {k: v[1] for k, v in batch.items()}
            step = make_train_step(model)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            with mesh:
                lowered = jitted.lower(param_shapes, opt_shapes, b_sds)
        elif shape.kind == "prefill":
            batch = build_inputs(cfg, shape, mesh, model)
            b_sds = {k: v[0] for k, v in batch.items()}
            b_shard = {k: v[1] for k, v in batch.items()}
            step = make_prefill_step(model)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            with mesh:
                lowered = jitted.lower(param_shapes, b_sds)
        else:  # decode
            inp = build_inputs(cfg, shape, mesh, model)
            cache_sds = jax.tree.map(lambda t: t[0], inp["cache"],
                                     is_leaf=lambda t: isinstance(t, tuple))
            cache_shard = jax.tree.map(lambda t: t[1], inp["cache"],
                                       is_leaf=lambda t: isinstance(t, tuple))
            step = make_serve_step(model)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, inp["token"][1], cache_shard,
                              inp["pos"][1]),
                out_shardings={"next_logits": None, "probe_hidden": None,
                               "cache": cache_shard},
                donate_argnums=(2,))
            with mesh:
                lowered = jitted.lower(param_shapes, inp["token"][0],
                                       cache_sds, inp["pos"][0])
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    from repro.launch.hlo_analysis import analyze as hlo_analyze
    ana = hlo_analyze(hlo)
    n_params = int(sum(np.prod(s.shape) for s in jax.tree.leaves(param_shapes)))

    rec.update({
        "ok": True,
        "lower_s": round(t_lower - t0, 2),
        "compile_s": round(t_compile - t_lower, 2),
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "n_params": n_params,
        "n_active_params": int(cfg.n_active_params_estimate),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes",
                                      getattr(mem, "temp_size_in_bytes", 0))),
        },
        "cost": {k: float(v) for k, v in dict(cost).items()
                 if isinstance(v, (int, float))},
        "hlo_analysis": {
            "flops": ana["flops"],
            "bytes": ana["bytes"],
            "collectives": ana["collectives"],
            "collective_counts": ana["collective_counts"],
            "collective_bytes_total": ana["collective_bytes_total"],
        },
        "hlo_bytes": len(hlo),
    })
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape_name}__{mesh_name}"
    if tag != "baseline":
        name += f"__{tag}"
    with open(out_dir / (name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    import gzip
    with gzip.open(out_dir / (name + ".hlo.gz"), "wt") as f:
        f.write(hlo)
    print(f"[dryrun] OK {name}: compile={rec['compile_s']}s "
          f"flops/dev={ana['flops']:.3e} "
          f"coll/dev={ana['collective_bytes_total']:.3e}B "
          f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--block-q", type=int, default=512)
    ap.add_argument("--sharding", choices=["tp-sp", "fsdp"], default="tp-sp",
                    help="fsdp: ZeRO-3 params + full data-parallel batch "
                         "(§Perf A; dense archs, train shapes)")
    ap.add_argument("--int8", action="store_true",
                    help="W8A16 weight quantization (§Perf C; serving)")
    args = ap.parse_args(argv)

    from repro.configs import ARCHS, INPUT_SHAPES

    combos = []
    if args.all:
        for a in sorted(ARCHS):
            for s in INPUT_SHAPES:
                combos.append((a, s.name))
    else:
        combos.append((args.arch, args.shape))

    failures = []
    for arch, shape in combos:
        mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
        fname = ARTIFACTS / f"{arch}__{shape}__{mesh_name}.json"
        if args.skip_done and fname.exists():
            with open(fname) as f:
                if json.load(f).get("ok"):
                    print(f"[dryrun] skip {fname.name} (done)")
                    continue
        extra_rules = None
        if args.sharding == "fsdp":
            extra_rules = FSDP_RULES
        extra_cfg = {"quant_int8": True} if args.int8 else None
        try:
            run_one(arch, shape, args.multi_pod, tag=args.tag,
                    block_q=args.block_q, extra_rules=extra_rules,
                    extra_cfg=extra_cfg)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape, str(e)[:200]))
            rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "ok": False, "error": str(e)[:2000]}
            ARTIFACTS.mkdir(parents=True, exist_ok=True)
            with open(fname, "w") as f:
                json.dump(rec, f, indent=1)
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)
    print("dry-run complete: all combinations lowered + compiled")


if __name__ == "__main__":
    main()
