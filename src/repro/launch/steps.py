"""Step functions lowered by the dry-run and launchers.

train_step   — fwd + CE loss (+MoE aux) + bwd + global-norm clip + AdamW
prefill_step — forward over the full prompt; returns last-token logits AND
               the last-token hidden state (the difficulty probe's input —
               this is where the paper's predictor taps the serving path
               for free)
serve_step   — ONE new token against a seq_len KV cache/state
"""
from __future__ import annotations

from typing import Any, Dict

import jax

from repro.models.model_zoo import Model
from repro.optim import adamw_update, clip_by_global_norm


def make_train_step(model: Model, *, lr: float = 1e-4, grad_clip: float = 1.0,
                    weight_decay: float = 0.1):
    def train_step(params, opt_state, batch: Dict[str, Any]):
        def loss_fn(p):
            return model.loss_fn(p, batch)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        params, opt_state = adamw_update(params, grads, opt_state, lr=lr,
                                         weight_decay=weight_decay)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch: Dict[str, Any]):
        logits, hidden, _ = model.forward(
            params, batch["tokens"], frames=batch.get("frames"),
            patches=batch.get("patches"))
        # last-token logits (next-token dist) + probe features
        return {"next_logits": logits[:, -1], "probe_hidden": hidden[:, -1]}

    return prefill_step


def make_serve_step(model: Model):
    def serve_step(params, token, cache, pos):
        logits, hidden, new_cache = model.decode_step(params, token, cache,
                                                      pos)
        return {"next_logits": logits[:, 0], "probe_hidden": hidden[:, 0],
                "cache": new_cache}

    return serve_step
