"""Re-run hlo_analysis over stored .hlo.gz artifacts (no recompilation).

Lets the analyzer evolve during the perf loop without paying compile time:
    PYTHONPATH=src python -m repro.launch.reanalyze
"""
from __future__ import annotations

import gzip
import json
from pathlib import Path

from repro.launch.hlo_analysis import analyze

ARTIFACTS = Path(__file__).resolve().parents[3] / "experiments" / "artifacts"


def main():
    n = 0
    for jf in sorted(ARTIFACTS.glob("*.json")):
        hf = jf.with_suffix("").with_suffix("")  # strip .json
        hf = jf.parent / (jf.stem + ".hlo.gz")
        if not hf.exists():
            continue
        rec = json.loads(jf.read_text())
        if not rec.get("ok"):
            continue
        with gzip.open(hf, "rt") as f:
            hlo = f.read()
        ana = analyze(hlo)
        rec["hlo_analysis"] = {
            "flops": ana["flops"],
            "bytes": ana["bytes"],
            "bytes_upper": ana.get("bytes_upper", ana["bytes"]),
            "collectives": ana["collectives"],
            "collective_counts": ana["collective_counts"],
            "collective_bytes_total": ana["collective_bytes_total"],
        }
        jf.write_text(json.dumps(rec, indent=1))
        n += 1
    print(f"reanalyzed {n} artifacts")


if __name__ == "__main__":
    main()
